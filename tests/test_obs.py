"""The repro.obs observability subsystem.

Contracts pinned here (docs/ARCHITECTURE.md section 12):

  * obs spec parsing (none/basic/full take no args, unknown names
    raise with options, register_obs extends the registry)
  * obs="none" IS the legacy engine (the protocol leaves the impl
    chain unwrapped) and obs is hash-excluded: every level shares ONE
    spec_hash
  * taps are observation-only: obs="full" runs are BITWISE obs="none"
    runs (params, metrics, history), on the scan and python engines,
    also chained behind a schedule + fault + transform stack -- and
    the recorded series are identical across engines
  * the obs x transform x schedule x count sweep grid compiles ONCE
    (round_traces == 1) with every non-none lane bitwise equal to its
    "none" twin; per-cell series carry the [seeds, rounds, ...] axes
  * SpanTracer nesting/export (Chrome trace-event JSON) round-trips;
    NullTracer is inert and refuses export
  * the unified Telemetry record surfaces on RunResult.telemetry with
    the legacy ``timings`` dict derived from it; ServeReport.obs
    carries the serving copy and prometheus_text renders a valid
    exposition (monotone cumulative buckets, +Inf == count)
  * a checkpoint's stream stamp refuses cross-obs-level resumes, and
    same-level resumes are bitwise
"""
import json
import re

import numpy as np
import pytest

import jax

from repro.api import ExperimentSpec, ServeRequest, build, \
    split_features
from repro.core.protocol import DeVertiFL, ProtocolConfig, \
    resolve_engine
from repro.core.sweep import SweepConfig, run_padded_cells
from repro.obs import (LATENCY_BUCKETS_S, NullTracer, ObsImpl,
                       SERIES_KEYS, SpanTracer, Telemetry,
                       TELEMETRY_SCHEMA_VERSION, get_obs_plan,
                       metrics_table, obs_names, prometheus_text,
                       register_obs)

TINY = dict(dataset="titanic", n_clients=3, rounds=2, epochs=2,
            seeds=(0,))
# taps chained behind the full engine stack
STACK = dict(schedule="stale_k:1", fault="crash:0.5", transform="int8")


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# registry + spec parsing
# ---------------------------------------------------------------------------
def test_obs_plan_parsing_and_registry_errors():
    assert get_obs_plan("none").level == 0
    assert get_obs_plan("basic").level == 1
    full = get_obs_plan("full")
    assert full.level == 2 and full.spec == "full"
    assert not full.is_none and get_obs_plan("none").is_none
    assert {"none", "basic", "full"} <= set(obs_names())
    with pytest.raises(ValueError, match="basic"):   # options listed
        get_obs_plan("nope")
    with pytest.raises(ValueError, match="no arguments"):
        get_obs_plan("full:3")
    with pytest.raises(ValueError, match="malformed"):
        get_obs_plan("  ")


def test_register_obs_custom_plan_parses_and_is_refused_in_lanes():
    def make(inner, n_clients, batch_size, width, rounds, args):
        return ObsImpl(get_obs_plan("full"), inner, n_clients,
                       batch_size, width, rounds)

    register_obs("test_tap", make, overwrite=True)
    plan = get_obs_plan("test_tap:7")
    assert plan.custom[0] == "test_tap" and plan.custom[2] == ("7",)
    assert not plan.is_none
    # custom plans provide their own impl; they cannot ride the
    # stacked lane state of a multi-level sweep
    impl = ObsImpl(get_obs_plan("full"), _dummy_inner(), 3, 16, 8,
                   rounds=2)
    with pytest.raises(ValueError, match="custom obs plan"):
        impl.init_state(None, obs=plan)


def _dummy_inner():
    from repro.schedule import LaneScheduleImpl
    return LaneScheduleImpl(0, 3, 16, 8)


# ---------------------------------------------------------------------------
# obs="none" is the legacy engine; obs is hash-excluded
# ---------------------------------------------------------------------------
def test_obs_none_leaves_engine_unwrapped_and_hash_is_shared():
    base = ExperimentSpec(**TINY)
    hashes = {base.replace(obs=o).spec_hash
              for o in ("none", "basic", "full")}
    assert len(hashes) == 1     # an obs level is NOT a new experiment
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, rounds=2)
    _, impl = resolve_engine(pcfg, *_engine_args(pcfg))
    assert impl is None          # untouched legacy sync path
    _, impl = resolve_engine(pcfg.replace(obs="basic"),
                             *_engine_args(pcfg))
    assert isinstance(impl, ObsImpl)


def _engine_args(pcfg):
    from repro.configs import get_config
    from repro.core.protocol import arch_for
    from repro.models.mlp_model import PaperMLP
    return PaperMLP(get_config(arch_for(pcfg.dataset))), 500


def test_obs_requires_devertifl_mode():
    with pytest.raises(ValueError, match="devertifl"):
        ExperimentSpec(**{**TINY, "mode": "non_federated"},
                       obs="basic")


# ---------------------------------------------------------------------------
# bitwise parity + recorded series
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("extra", [{}, STACK],
                         ids=["sync", "sched+fault+wire"])
def test_obs_full_is_bitwise_none_and_records_series(extra):
    a = build(ExperimentSpec(**TINY, **extra)).run()
    b = build(ExperimentSpec(**TINY, **extra, obs="full")).run()
    assert _leaves_equal(a.params, b.params)
    assert a.metrics == b.metrics
    for ha, hb in zip(a.history, b.history):
        np.testing.assert_array_equal(ha["round_losses"],
                                      hb["round_losses"])
    ser = b.telemetry.series
    assert set(ser) == set(SERIES_KEYS)
    R, n = TINY["rounds"], TINY["n_clients"]
    assert ser["loss"].shape == (R,)
    assert ser["exchange_norm"].shape == (R, n)
    assert ser["grad_norm"].shape == (R, n)
    assert (ser["loss"] > 0).all()
    assert (ser["exchange_norm"] > 0).any()
    assert (ser["grad_norm"] > 0).any()
    if extra:
        assert (ser["staleness"] == 1).all()
        assert (ser["encoded_bytes"] > 0).all()
    # the obs-free run records nothing but keeps the unified record
    assert a.telemetry.series is None
    assert a.timings == a.telemetry.to_timings()


def test_obs_basic_skips_per_client_series():
    res = build(ExperimentSpec(**TINY, obs="basic")).run()
    ser = res.telemetry.series
    assert (ser["loss"] > 0).all()
    # basic never traces the norm taps (static level bound): the
    # per-client series stay exact zeros
    assert (ser["exchange_norm"] == 0).all()
    assert (ser["grad_norm"] == 0).all()


def test_obs_series_identical_across_scan_and_python_engines():
    a = build(ExperimentSpec(**TINY, **STACK, obs="full")).run()
    b = build(ExperimentSpec(**TINY, **STACK, obs="full",
                             engine="python")).run()
    assert _leaves_equal(a.params, b.params)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(a.telemetry.series[k],
                                      b.telemetry.series[k])


# ---------------------------------------------------------------------------
# sweep lanes: one compile, none-lane parity, per-cell series
# ---------------------------------------------------------------------------
def test_obs_grid_compiles_once_with_none_lanes_bitwise():
    scfg = SweepConfig(datasets=("titanic",), modes=("devertifl",),
                       client_counts=(2, 3), seeds=(0,), rounds=2,
                       epochs=1, schedules=("sync", "stale_k:1"),
                       transforms=("none", "int8"),
                       obs=("none", "basic", "full"))
    out = run_padded_cells("titanic", "devertifl", scfg)
    assert out["round_traces"] == 1
    assert out["obs"] == ["none", "basic", "full"]
    cells = out["cells"]
    assert len(cells) == 3 * 2 * 2 * 2
    for key, cell in cells.items():
        level = key.split("/")[0]
        assert cell["obs"] == level
        if level == "none":
            continue
        twin = cells["none/" + key.split("/", 1)[1]]
        assert cell["acc_per_seed"] == twin["acc_per_seed"]
        assert cell["f1_per_seed"] == twin["f1_per_seed"]
        ser = cell["obs_series"]
        # leading seed axis, then rounds (and the padded client axis)
        assert ser["loss"].shape == (1, 2)
        assert ser["exchange_norm"].shape == (1, 2, 3)
        if level == "full":
            assert (ser["grad_norm"] > 0).any()
        else:
            assert (ser["grad_norm"] == 0).all()


def test_obs_sweep_refuses_custom_plans_and_non_devertifl():
    register_obs("test_tap2", lambda **kw: None, overwrite=True)
    scfg = SweepConfig(datasets=("titanic",), modes=("devertifl",),
                       client_counts=(2,), seeds=(0,), rounds=1,
                       epochs=1, obs=("none", "test_tap2"))
    with pytest.raises(ValueError, match="custom obs"):
        run_padded_cells("titanic", "devertifl", scfg)
    scfg2 = scfg.__class__(**{**scfg.__dict__,
                              "modes": ("verticomb",),
                              "obs": ("basic",)})
    with pytest.raises(ValueError, match="devertifl"):
        run_padded_cells("titanic", "verticomb", scfg2)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_span_tracer_nesting_export_and_summary(tmp_path):
    tr = SpanTracer()
    assert tr.active
    with tr.span("outer", cat="t"):
        with tr.span("inner", cat="t", round=1):
            tr.instant("tick", x=2)
    recs = tr.to_records()
    by = {r["name"]: r for r in recs}
    assert by["outer"]["depth"] == 0 and by["inner"]["depth"] == 1
    assert by["inner"]["args"]["round"] == 1
    assert by["tick"]["ph"] == "i"
    assert by["outer"]["dur"] >= by["inner"]["dur"] >= 0
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i"}
    for e in evs:                       # Perfetto-required fields
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        assert ("dur" in e) == (e["ph"] == "X")
    text = tr.summary()
    assert "outer" in text and "inner" in text


def test_null_tracer_is_inert_and_refuses_export():
    tr = NullTracer()
    assert not tr.active
    with tr.span("x"):
        tr.instant("y")
    assert tr.to_records() == []
    with pytest.raises(ValueError, match="obs"):
        tr.export("/tmp/never.json")


def test_session_tracer_spans_cover_the_run(tmp_path):
    sess = build(ExperimentSpec(**TINY, obs="basic"))
    sess.run()
    recs = sess.tracer.to_records()
    names = [r["name"] for r in recs]
    assert names.count("round") == TINY["rounds"]
    assert "build" in names and "eval" in names
    path = sess.tracer.export(str(tmp_path / "t.json"))
    assert json.load(open(path))["traceEvents"]
    # obs="none" sessions carry the no-op tracer
    assert not build(ExperimentSpec(**TINY)).tracer.active


# ---------------------------------------------------------------------------
# unified telemetry record
# ---------------------------------------------------------------------------
def test_telemetry_record_and_legacy_timings_alias():
    res = build(ExperimentSpec(**TINY, **STACK, obs="full")).run()
    tel = res.telemetry
    assert tel.schema_version == TELEMETRY_SCHEMA_VERSION
    assert res.schema_version == 5
    assert res.timings == tel.to_timings()
    assert res.timings["fault"] == tel.fault
    assert res.timings["wire"] == tel.wire
    d = res.to_dict()
    json.dumps(d)                        # JSON-safe end to end
    assert d["telemetry"]["series"]["loss"] == \
        list(tel.series["loss"])
    # custom runners lift legacy dicts into the record
    lifted = Telemetry.from_timings({"wall_s": 2.0, "fault": {"x": 1}})
    assert lifted.wall_s == 2.0 and lifted.fault == {"x": 1}
    assert "obs=" not in metrics_table(res)      # renders, no crash
    assert "steps/sec" in metrics_table(res)


# ---------------------------------------------------------------------------
# serving: ServeReport.obs + prometheus exposition
# ---------------------------------------------------------------------------
_LINE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
                   r'(\{[a-zA-Z_]+="[^"]*"(,[a-zA-Z_]+="[^"]*")*\})?'
                   r" -?[0-9.e+Inf-]+$")


@pytest.fixture(scope="module")
def served():
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=1,
                          epochs=1, seeds=(0,), eval_every=0,
                          obs="basic")
    sess = build(spec)
    sess.run()
    lay = sess.federation.layout
    xte = np.asarray(sess.federation.xte)
    reqs = [ServeRequest(uid=f"u{i}", entity_id=f"e{i}",
                         slices=split_features(lay, xte[i]))
            for i in range(6)]
    return sess, sess.serve(reqs, max_slots=3)


def test_serve_report_carries_unified_obs_record(served):
    sess, rep = served
    assert rep.schema_version == 2
    obs = rep.obs
    assert obs["schema_version"] == TELEMETRY_SCHEMA_VERSION
    assert obs["serve"]["submitted"] == rep.counters["submitted"]
    assert obs["serve"]["completed"] == rep.counters["completed"]
    assert obs["serve"]["throughput_rps"] == rep.throughput_rps
    json.dumps(rep.to_dict())
    # request lifecycle shows up on the session tracer
    names = {r["name"] for r in sess.tracer.to_records()}
    assert {"submit", "admit", "complete", "serve_step"} <= names


def test_prometheus_text_is_a_valid_exposition(served):
    _, rep = served
    text = prometheus_text(rep)
    assert text.endswith("\n")
    for ln in text.splitlines():
        if ln.startswith("#"):
            assert re.match(r"^# (HELP|TYPE) repro_serve_\w+ ", ln)
        else:
            assert _LINE.match(ln), ln
    assert f"repro_serve_submitted_total {rep.counters['submitted']}" \
        in text
    # cumulative latency histogram: monotone, +Inf equals _count
    buckets = re.findall(
        r'repro_serve_latency_seconds_bucket\{le="([^"]+)"\} (\d+)',
        text)
    assert buckets[-1][0] == "+Inf"
    assert [float(b[0]) for b in buckets[:-1]] == \
        list(LATENCY_BUCKETS_S)
    counts = [int(b[1]) for b in buckets]
    assert counts == sorted(counts)
    total = int(re.search(
        r"repro_serve_latency_seconds_count (\d+)", text).group(1))
    assert counts[-1] == total == rep.counters["completed"]


# ---------------------------------------------------------------------------
# checkpoint stream stamp
# ---------------------------------------------------------------------------
def test_obs_checkpoint_stamp_refuses_cross_level_resume(tmp_path):
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", n_clients=3, epochs=1, seeds=(0,),
              obs="basic")
    full = build(ExperimentSpec(rounds=4, **kw)).run()
    build(ExperimentSpec(rounds=2, checkpoint_dir=d,
                         checkpoint_every=1, **kw)).run()
    res = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                               checkpoint_every=1, **kw)).resume()
    assert res.resumed_from == 2
    assert res.metrics == full.metrics
    with pytest.raises(ValueError, match="or obs level"):
        build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                             checkpoint_every=1,
                             **{**kw, "obs": "full"})).resume()


def test_obs_free_checkpoints_refuse_obs_resume(tmp_path):
    """An obs-free checkpoint has no series buffers to restore: the
    stream stamp (sync vs sync|obs=basic) refuses the splice."""
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", n_clients=3, epochs=1, seeds=(0,))
    build(ExperimentSpec(rounds=2, checkpoint_dir=d,
                         checkpoint_every=1, **kw)).run()
    with pytest.raises(ValueError, match="or obs level"):
        build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                             checkpoint_every=1, obs="basic",
                             **kw)).resume()
