"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rwkv6_scan import rwkv6_scan, rwkv6_scan_ref
from repro.kernels.vfl_matmul import vfl_matmul, vfl_matmul_ref


def allclose(a, b, dtype):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    scale = max(1.0, float(np.abs(b).max()))
    np.testing.assert_allclose(a, b, atol=tol * scale, rtol=tol)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,Kl,Kf,off", [
    (32, 128, 512, 0), (64, 128, 512, 128), (128, 256, 1024, 512),
    (16, 128, 128, 0),
])
def test_vfl_matmul(M, Kl, Kf, off, dtype):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (M, Kl), dtype)
    w = jax.random.normal(k2, (Kf, 256), dtype)
    out = vfl_matmul(x, w, off)
    ref = vfl_matmul_ref(x, w, off)
    allclose(out, ref, dtype)


@pytest.mark.parametrize("M,Kl,Kf,off,bk", [
    (16, 128, 512, 128, 128),    # aligned to default block
    (8, 56, 140, 28, 28),        # mnist-style row-block alignment
    (32, 128, 128, 0, 128),      # whole-width client
    (6, 3, 9, 3, 3),             # titanic-sized tiny blocks
])
def test_vfl_matmul_grads_match_ref(M, Kl, Kf, off, bk):
    """custom_vjp vs autodiff through the zeropad oracle: dx is the
    sliced g @ W.T, dW scatter-adds into the client's row block (exact
    zeros elsewhere).  interpret=True so the CPU suite exercises the
    kernel's backward without a TPU."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (M, Kl), jnp.float32)
    w = jax.random.normal(ks[1], (Kf, 32), jnp.float32)
    t = jax.random.normal(ks[2], (M, 32), jnp.float32)  # cotangent seed

    def loss_kernel(x, w):
        return (vfl_matmul(x, w, off, bk=bk, interpret=True) * t).sum()

    def loss_ref(x, w):
        return (vfl_matmul_ref(x, w, off) * t).sum()

    gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    allclose(gx, gx_r, jnp.float32)
    allclose(gw, gw_r, jnp.float32)
    # rows of W outside the client's slice get an exact zero gradient
    gw_np = np.asarray(gw)
    assert np.all(gw_np[:off] == 0) and np.all(gw_np[off + Kl:] == 0)


def test_vfl_matmul_value_and_grad_under_jit_scan():
    """The vjp composes with jit+scan the way the protocol engine uses
    it (value_and_grad inside a scanned training step)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    xs = jax.random.normal(ks[0], (4, 8, 56), jnp.float32)
    w = jax.random.normal(ks[1], (140, 16), jnp.float32)

    @jax.jit
    def train(w):
        def body(w, x):
            def loss(w):
                return (vfl_matmul(x, w, 28, bk=28) ** 2).sum()
            l, g = jax.value_and_grad(loss)(w)
            return w - 0.01 * g, l
        return jax.lax.scan(body, w, xs)

    w2, losses = train(w)
    def loss_ref(w):
        return (vfl_matmul_ref(xs[0], w, 28) ** 2).sum()
    assert np.all(np.isfinite(np.asarray(losses)))
    # one reference step reproduces the first scanned step
    w_ref = w - 0.01 * jax.grad(loss_ref)(w)
    @jax.jit
    def one(w):
        def loss(w):
            return (vfl_matmul(xs[0], w, 28, bk=28) ** 2).sum()
        return w - 0.01 * jax.grad(loss)(w)
    allclose(one(w), w_ref, jnp.float32)


def test_vfl_matmul_skips_zero_blocks():
    """The kernel must produce the same result regardless of what lives
    outside the client's slice of W-rows' input (it never reads x
    outside the slice -- x IS the slice)."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (32, 128), jnp.float32)
    w = jax.random.normal(key, (512, 128), jnp.float32)
    out1 = vfl_matmul(x, w, 128)
    # zeroing W rows outside the slice must not change the result
    w2 = w.at[:128].set(0).at[256:].set(0)
    out2 = vfl_matmul(x, w2, 128)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd,causal,window,cap", [
    (2, 4, 2, 256, 64, True, None, 0.0),
    (1, 4, 4, 256, 64, True, 128, 0.0),
    (1, 8, 2, 128, 64, True, None, 50.0),
    (2, 2, 2, 256, 64, False, None, 0.0),
    (1, 2, 1, 512, 128, True, 256, 30.0),
])
def test_flash_attention(B, H, KV, S, hd, causal, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=cap)
    allclose(out, ref, dtype)


def test_flash_attention_block_sizes():
    """Result must be block-size independent."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [flash_attention(q, k, v, bq=bq, bk=bk)
            for (bq, bk) in [(64, 64), (128, 128), (256, 64), (64, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (2, 128, 2, 64, 32), (1, 256, 4, 64, 64), (2, 64, 2, 128, 64),
])
def test_rwkv6_scan(B, T, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = (jax.random.normal(ks[1], (B, T, H, hd)) * 0.3).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, hd), dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
         * 0.5 + 0.45).astype(dtype)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.2).astype(jnp.float32)
    out = rwkv6_scan(r, k, v, w, u, chunk=chunk)
    ref = rwkv6_scan_ref(r, k, v, w, u)
    allclose(out, ref, dtype)


def test_rwkv6_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, T, H, hd = 1, 128, 2, 64
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd)) * 0.2
    outs = [rwkv6_scan(r, k, v, w, u, chunk=c) for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
from repro.kernels.moe_router import moe_router, moe_router_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,D,N,bd,chunk", [
    (1, 64, 128, 16, 64, 32), (2, 128, 256, 8, 128, 64),
    (1, 96, 128, 16, 128, 32),
])
def test_mamba_scan(B, T, D, N, bd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D, N)))
         * 0.5 + 0.45).astype(dtype)
    bx = (jax.random.normal(ks[1], (B, T, D, N)) * 0.2).astype(dtype)
    c = jax.random.normal(ks[2], (B, T, N), dtype)
    out = mamba_scan(a, bx, c, bd=bd, chunk=chunk)
    ref = mamba_scan_ref(a, bx, c)
    allclose(out, ref, dtype)


def test_mamba_scan_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 128, 128, 8))) * 0.5 + 0.4
    bx = jax.random.normal(ks[1], (1, 128, 128, 8)) * 0.2
    c = jax.random.normal(ks[2], (1, 128, 8))
    outs = [mamba_scan(a, bx, c, chunk=ch) for ch in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("T,E,k", [(256, 64, 6), (128, 8, 2), (384, 16, 4)])
def test_moe_router(T, E, k):
    logits = jax.random.normal(jax.random.PRNGKey(1), (T, E)) * 2
    w, i, s = moe_router(logits, k)
    wr, ir, sr = moe_router_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-4)
    # weights renormalized
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
