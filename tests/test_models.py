"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned architecture family runs one forward + one train step on
CPU; output shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.configs.reduced import reduced_config
from repro.models import build_model
from repro.models.model import padded_vocab
from repro.optim import adam

ARCHS = [n for n in list_configs() if not n.startswith("paper-")]


def make_batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.modality != "text":
        P = cfg.num_prefix_embeddings
        batch["prefix_emb"] = jax.random.normal(key, (B, P, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = reduced_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = jax.jit(model.forward_logits)(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, padded_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    opt = adam(1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(model.loss,
                                              has_aux=True)(params, batch)
        params, opt_state, _ = opt.update(grads, opt_state, params,
                                          jnp.zeros((), jnp.int32))
        return params, opt_state, loss

    l0 = None
    for i in range(3):
        params, opt_state, loss = step(params, opt_state, batch)
        assert bool(jnp.isfinite(loss)), f"step {i} loss not finite"
        if l0 is None:
            l0 = float(loss)
    # same batch thrice: loss must drop
    assert float(loss) < l0


ASSIGNED = [a for a in ARCHS if not a.endswith("-swa")]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """Full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_param_count_sanity():
    """Total param counts should be in the ballpark of the model names."""
    expect_range = {
        "qwen2-7b": (6e9, 9e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "jamba-v0.1-52b": (40e9, 60e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "llava-next-34b": (30e9, 40e9),
        "qwen1.5-0.5b": (0.4e9, 0.8e9),
        "mixtral-8x22b": (120e9, 150e9),
        "qwen1.5-4b": (3e9, 5e9),
        "gemma2-2b": (2e9, 3.5e9),
    }
    for arch, (lo, hi) in expect_range.items():
        n = get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo},{hi}]"


def test_moe_active_less_than_total():
    for arch in ("mixtral-8x22b", "deepseek-moe-16b", "jamba-v0.1-52b"):
        pc = get_config(arch).param_counts()
        assert pc["active"] < 0.6 * pc["total"]
