"""Integration: token-by-token decode with KV cache / recurrent state
must reproduce the full-sequence forward logits for every architecture
family (this is the invariant that makes decode_32k/long_500k dry-runs
meaningful)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced_config
from repro.models import build_model

# one representative per family / attention variant
ARCHS = ["qwen2-7b", "gemma2-2b", "mixtral-8x22b", "rwkv6-1.6b",
         "jamba-v0.1-52b", "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    # keep dropout-free float32 exactness
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encoder_decoder:
        enc_in = jax.random.normal(
            key, (B, cfg.num_prefix_embeddings, cfg.d_model))
        batch["prefix_emb"] = enc_in
    full_logits, _ = jax.jit(model.forward_logits)(params, batch)

    state = model.init_decode_state(B, S)
    if cfg.is_encoder_decoder:
        state["enc"] = model._encode(params, batch["prefix_emb"])
    step = jax.jit(model.decode_step)
    dec_logits = []
    for t in range(S):
        lg, state = step(params, state, tokens[:, t:t + 1])
        dec_logits.append(lg[:, 0])
    dec_logits = jnp.stack(dec_logits, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-3, rtol=2e-3,
        err_msg=f"{arch}: decode diverges from forward")


def test_swa_ring_buffer_matches_full_window():
    """Windowed decode with a ring buffer (cache size == window) must
    match decode with a full-size cache."""
    cfg = reduced_config("mixtral-8x22b")  # swa window 16 (reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 32
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    step = jax.jit(model.decode_step)

    # ring buffer: init_cache clamps attn caches to window size
    state_ring = model.init_decode_state(B, cfg.window_size)
    state_full = model.init_decode_state(B, S)
    out_r, out_f = [], []
    for t in range(S):
        lr, state_ring = step(params, state_ring, tokens[:, t:t + 1])
        lf, state_full = step(params, state_full, tokens[:, t:t + 1])
        out_r.append(lr)
        out_f.append(lf)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(out_r, 1)),
                               np.asarray(jnp.concatenate(out_f, 1)),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """prefill (forward-only, cache-populating) then token-by-token
    decode must equal the full forward -- validates the inference path
    the prefill_32k / decode_32k dry-runs lower."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, extra = 2, 16, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        batch["prefix_emb"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_prefix_embeddings,
                                    cfg.d_model))
    full_logits, _ = jax.jit(model.forward_logits)(params, batch)

    pre = {"tokens": toks[:, :S]}
    if "prefix_emb" in batch:
        pre["prefix_emb"] = batch["prefix_emb"]
    logits_p, state = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=S + extra))(params, pre)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                               np.asarray(full_logits[:, S - 1],
                                          np.float32),
                               atol=2e-3, rtol=2e-3)
    step = jax.jit(model.decode_step)
    for t in range(S, S + extra):
        lg, state = step(params, state, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=2e-3, rtol=2e-3, err_msg=f"{arch} pos {t}")
