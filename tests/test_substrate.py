"""Unit tests for substrate pieces: attention chunking, optimizers,
checkpointing, metrics, schedules, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint, latest_step
from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.data import MarkovLM, make_dataset, markov_lm_batches
from repro.metrics import accuracy, f1_score
from repro.models import attention as A
from repro.models import layers as L
from repro.optim import adam, linear_warmup_cosine, sgd


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def test_chunked_attention_matches_full():
    cfg = reduced_config("qwen2-7b")
    key = jax.random.PRNGKey(0)
    params = A.attn_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    pos = jnp.arange(64)
    full = A.attn_apply(params, x, pos, cfg)
    # force chunking by monkeypatching _pick_chunk
    orig = A._pick_chunk
    A._pick_chunk = lambda S, Skv, w: 16
    try:
        chunked = A.attn_apply(params, x, pos, cfg)
    finally:
        A._pick_chunk = orig
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)


def test_swa_chunked_matches_full_window_mask():
    cfg = reduced_config("mixtral-8x22b")
    key = jax.random.PRNGKey(1)
    params = A.attn_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    pos = jnp.arange(64)
    w = 16
    full = A.attn_apply(params, x, pos, cfg, layer_window=w)
    orig = A._pick_chunk
    A._pick_chunk = lambda S, Skv, win: 16
    try:
        chunked = A.attn_apply(params, x, pos, cfg, layer_window=w)
    finally:
        A._pick_chunk = orig
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)


def test_rope_rotation_properties():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, 10000.0)
    # norm-preserving
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(x[:, 0]), np.asarray(y[:, 0]),
                               atol=1e-6)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = L.softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(np.asarray(L.softcap(x, 0.0)),
                               np.asarray(x))


# ---------------------------------------------------------------------------
# optimizers / schedules
# ---------------------------------------------------------------------------
def test_adam_converges_quadratic():
    opt = adam(0.1, max_grad_norm=None)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = opt.init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params,
                                      jnp.asarray(i))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_step():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    params, state, _ = opt.update({"w": jnp.array([1.0])}, state, params,
                                  jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(params["w"]), [0.9])


def test_grad_clip():
    from repro.optim import clip_by_global_norm
    g = {"a": jnp.ones((100,)) * 10}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_schedule_warmup_and_decay():
    fn = linear_warmup_cosine(1.0, 10, 100)
    assert float(fn(0)) < 0.2
    assert abs(float(fn(10)) - 1.0) < 0.05
    assert float(fn(99)) < 0.2


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.ones((4,), jnp.int32)}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = load_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_f1_perfect_and_worst():
    y = np.array([0, 1, 1, 0, 1])
    assert f1_score(y, y, average="binary") == 1.0
    assert f1_score(y, 1 - y, average="binary") == 0.0
    assert accuracy(y, y) == 1.0


def test_f1_macro_known_value():
    y_true = np.array([0, 0, 1, 1, 2, 2])
    y_pred = np.array([0, 0, 1, 0, 2, 1])
    # class0: p=2/3, r=1 -> 0.8; class1: p=1/2, r=1/2 -> 0.5;
    # class2: p=1, r=1/2 -> 2/3
    expect = (0.8 + 0.5 + 2 / 3) / 3
    assert abs(f1_score(y_true, y_pred, "macro") - expect) < 1e-9


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_datasets_shapes():
    for name, nf, nc in (("mnist", 784, 10), ("fmnist", 784, 10),
                         ("titanic", 9, 2), ("bank", 51, 2)):
        xtr, ytr, xte, yte = make_dataset(name, n=500)
        assert xtr.shape[1] == nf
        assert set(np.unique(ytr)) <= set(range(nc))
        assert len(xte) > 0


def test_markov_lm_learnable():
    """The synthetic LM stream has sub-uniform entropy (learnable)."""
    lm = MarkovLM(64, branching=2, seed=0)
    rng = np.random.default_rng(0)
    toks = lm.sample(rng, 4, 200)
    # bigram predictability: next token must come from 2 candidates
    ok = 0
    for b in range(4):
        for t in range(200):
            ok += toks[b, t + 1] in lm.next_states[toks[b, t]]
    assert ok == 4 * 200


def test_lm_batch_iterator():
    it = markov_lm_batches(128, 2, 16)
    b = next(it)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
