"""Legacy LM continuous-batching engine (repro.serving.engine):
batched greedy generation must equal sequential single-request
generation (slot isolation + prefill splicing are exact), slots refill
from the queue, stop tokens truncate, and temperature=0 decoding is
deterministic across runs.  The federated serving path has its own
harness in tests/test_serving.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def sequential_generate(model, params, prompt, n_new, cache_len):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if model.cfg.is_encoder_decoder or model.cfg.modality != "text":
        batch["prefix_emb"] = jnp.zeros(
            (1, model.cfg.num_prefix_embeddings, model.cfg.d_model))
    logits, st = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(params,
                                                               batch)
    toks = [int(jnp.argmax(logits[0, -1]))]
    step = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        lg, st = step(params, st, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-1.6b",
                                  "mixtral-8x22b"])
def test_engine_matches_sequential(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 3, 7)]
    n_new = 6

    engine = ServingEngine(model, params, max_batch=2, cache_len=64)
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    out = engine.run()
    assert engine.stats["done"] == len(prompts)

    for i, p in enumerate(prompts):
        ref = sequential_generate(model, params, p, n_new, 64)
        assert out[i] == ref, f"{arch} request {i}: {out[i]} vs {ref}"


def test_engine_stop_token_and_refill():
    cfg = reduced_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=1, cache_len=64)
    # more requests than slots -> queue drains via refill
    for i in range(3):
        engine.submit(Request(uid=i, prompt=[1, 2, 3],
                              max_new_tokens=4))
    out = engine.run()
    assert sorted(out) == [0, 1, 2]
    assert all(len(v) <= 4 for v in out.values())


def test_engine_stop_token_truncates():
    """A request whose greedy stream hits its stop token ends early
    and the stop token itself is not emitted."""
    cfg = reduced_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # discover the greedy stream first, then stop on its 2nd token
    ref_engine = ServingEngine(model, params, max_batch=1, cache_len=64)
    ref_engine.submit(Request(uid=0, prompt=[1, 2, 3],
                              max_new_tokens=6))
    ref = ref_engine.run()[0]
    assert len(ref) == 6
    engine = ServingEngine(model, params, max_batch=1, cache_len=64)
    engine.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=6,
                          stop_token=ref[1]))
    out = engine.run()[0]
    assert out == ref[:1], (out, ref)


def test_engine_greedy_deterministic():
    """temperature=0 decoding is a pure function of (params, prompts):
    two engines over the same request set emit identical streams, and
    slot count does not change any request's tokens."""
    cfg = reduced_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[1, 2, 3], [7, 5], [9, 9, 2, 4]]

    def run(max_batch, seed):
        engine = ServingEngine(model, params, max_batch=max_batch,
                               cache_len=64, seed=seed)
        for i, p in enumerate(prompts):
            engine.submit(Request(uid=i, prompt=p, max_new_tokens=5))
        return engine.run()

    a, b, c = run(2, 0), run(2, 1), run(3, 0)
    assert a == b, "greedy decode must ignore the sampling seed"
    assert a == c, "slot count must not change greedy streams"


def test_engine_stats_track_queue_and_done():
    cfg = reduced_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=1, cache_len=64)
    for i in range(2):
        engine.submit(Request(uid=i, prompt=[1, 2], max_new_tokens=3))
    assert engine.stats == {"active": 0, "queued": 2, "done": 0}
    engine.run()
    assert engine.stats == {"active": 0, "queued": 0, "done": 2}
