#!/usr/bin/env bash
# Fast CI gate, lane-selectable:
#
#   scripts/ci.sh                 # all lanes (local pre-commit default)
#   scripts/ci.sh fast bench      # `fast` pytest marker + bench smoke
#   scripts/ci.sh examples        # examples smoke (reduced configs)
#   scripts/ci.sh schedule-smoke  # exchange-schedule suite + bench
#   scripts/ci.sh fault-smoke     # fault-injection suite + bench + audit
#   scripts/ci.sh wire-smoke      # wire-transform suite + bench + audit
#   scripts/ci.sh serving-smoke   # federated serving suite + bench
#   scripts/ci.sh obs-smoke       # observability suite + bench + CLI
#
# Lanes: fast (the `fast` pytest marker suite), bench
# (benchmarks/run.py --smoke: protocol engine + schedule + sweep
# throughput and the staleness + fault + serving sweeps at toy sizes,
# no result-file writes), schedule-smoke (tests/test_schedule.py --
# the repro.schedule subsystem: sync bitwise pins, stale/double-buffer/
# partial rounds, schedule lane sweeps), fault-smoke
# (tests/test_faults.py -- the repro.faults subsystem: fault="none"
# bitwise pins, crash/straggle/corrupt determinism, guard quarantine,
# rollback-retry recovery -- plus the faults bench smoke and a static
# audit over a faulted combo subset), wire-smoke (tests/test_wire.py
# -- the repro.wire subsystem: transform="none" bitwise pins,
# int8/topk/dp codec exactness, compile-once wire lanes, encoded
# serving-cache payloads, skewed layouts -- plus the wire bench smoke
# and a static audit over the hot transform combos), serving-smoke
# (tests/test_serving.py + tests/test_serving_engine.py -- the
# serve()==predict() bitwise parity pin, slot-scheduler property
# suite, and the legacy LM engine -- plus the offered-load serving
# bench at toy sizes writing a throwaway BENCH_serving.json),
# obs-smoke (tests/test_obs.py -- the repro.obs subsystem: obs="none"
# bitwise pins, tap series determinism, compile-once obs lanes, span
# tracer export, Prometheus exposition, obs checkpoint stamps -- plus
# the tap-overhead bench smoke writing a throwaway BENCH_obs.json and
# one `python -m repro.obs` CLI pass exporting a trace),
# examples (examples/quickstart.py, examples/federated_training.py
# --smoke, examples/staleness_sweep.py and examples/serving.py
# --smoke -- keeps the spec-driven README
# snippets from rotting), analysis (python -m repro.analysis: the
# static taint/deadness/retrace audit over the full registered
# mode x schedule x first-layer x fault grid; exits 1 on any unwaived
# violation).  Full tier-1 is
# `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

LANES=("${@:-all}")
for lane in "${LANES[@]}"; do
  case "$lane" in
    all|fast|bench|schedule-smoke|fault-smoke|wire-smoke|serving-smoke|obs-smoke|examples|analysis) ;;
    *) echo "ci.sh: unknown lane '$lane' (lanes: all fast bench schedule-smoke fault-smoke wire-smoke serving-smoke obs-smoke examples analysis)" >&2
       exit 2 ;;
  esac
done
want() {
  local lane
  for lane in "${LANES[@]}"; do
    [[ "$lane" == "all" || "$lane" == "$1" ]] && return 0
  done
  return 1
}

if want fast; then
  echo "== pytest -m fast =="
  python -m pytest -q -m fast
fi

if want bench; then
  echo "== benchmarks/run.py --smoke =="
  python -m benchmarks.run --smoke
fi

if want schedule-smoke; then
  echo "== tests/test_schedule.py (exchange-schedule suite) =="
  # (the staleness bench smoke itself runs in the bench lane via
  # benchmarks/run.py --smoke, and test_staleness_bench_smoke_appends
  # covers it here -- no second standalone invocation)
  python -m pytest -q tests/test_schedule.py
fi

if want fault-smoke; then
  echo "== tests/test_faults.py (fault-injection suite) =="
  python -m pytest -q tests/test_faults.py
  echo "== benchmarks/faults.py --smoke =="
  python -m benchmarks.faults --smoke
  echo "== repro.analysis (faulted combo subset) =="
  python -m repro.analysis -q --out /dev/null --modes devertifl \
    --schedules sync stale_k:2 --first-layers slice \
    --faults none "crash:0.2:2+corrupt:0.05" "straggle:0.5:2" \
    --no-lane-check
fi

if want wire-smoke; then
  echo "== tests/test_wire.py (wire-transform suite) =="
  python -m pytest -q tests/test_wire.py
  echo "== benchmarks/wire.py --smoke =="
  # --out keeps the smoke entry out of benchmarks/results/ (-u: fresh
  # name, no pre-created empty file for the append reader to
  # quarantine)
  python -m benchmarks.wire --smoke --out "$(mktemp -u)"
  echo "== repro.analysis (wired combo subset) =="
  python -m repro.analysis -q --out /dev/null --modes devertifl \
    --schedules sync --first-layers slice \
    --transforms none "int8+dp:0.1" "topk:0.5" \
    --no-lane-check
fi

if want serving-smoke; then
  echo "== tests/test_serving.py + tests/test_serving_engine.py (serving suites) =="
  python -m pytest -q tests/test_serving.py tests/test_serving_engine.py
  echo "== benchmarks/serving.py --smoke =="
  # --out exercises the BENCH_serving.json append path without
  # touching benchmarks/results/ (-u: fresh name, no pre-created
  # empty file for the append reader to quarantine)
  python -m benchmarks.serving --smoke --out "$(mktemp -u)"
fi

if want obs-smoke; then
  echo "== tests/test_obs.py (observability suite) =="
  python -m pytest -q tests/test_obs.py
  echo "== benchmarks/obs.py --smoke =="
  # --out keeps the smoke entry out of benchmarks/results/ (-u: fresh
  # name, no pre-created empty file for the append reader to
  # quarantine)
  python -m benchmarks.obs --smoke --out "$(mktemp -u)"
  echo "== python -m repro.obs (CLI smoke + trace export) =="
  python -m repro.obs --rounds 2 --n-samples 512 \
    --trace-out "$(mktemp -u --suffix=.json)"
fi

if want analysis; then
  echo "== python -m repro.analysis (static audit, full grid) =="
  python -m repro.analysis -q --out /dev/null
fi

if want examples; then
  echo "== examples smoke (reduced config) =="
  python examples/quickstart.py
  python examples/federated_training.py --smoke
  python examples/staleness_sweep.py
  python examples/wire_tradeoff.py --smoke
  python examples/serving.py --smoke
fi

echo "ci.sh: all green (${LANES[*]})"
