#!/usr/bin/env bash
# Fast CI gate: the `fast` pytest marker suite plus the benchmark smoke
# lane (protocol engine + sweep throughput at toy sizes, no result-file
# writes).  Keeps the README quickstart commands and the smoke lanes
# from rotting.  Full tier-1 is `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest -m fast =="
python -m pytest -q -m fast

echo "== benchmarks/run.py --smoke =="
python -m benchmarks.run --smoke

echo "ci.sh: all green"
