#!/usr/bin/env bash
# Fast CI gate, lane-selectable:
#
#   scripts/ci.sh                 # all lanes (local pre-commit default)
#   scripts/ci.sh fast bench      # `fast` pytest marker + bench smoke
#   scripts/ci.sh examples        # examples smoke (reduced configs)
#
# Lanes: fast (the `fast` pytest marker suite), bench
# (benchmarks/run.py --smoke: protocol engine + sweep throughput at toy
# sizes, no result-file writes), examples (examples/quickstart.py and
# examples/federated_training.py --smoke -- keeps the spec-driven
# README snippets from rotting).  Full tier-1 is
# `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

LANES=("${@:-all}")
for lane in "${LANES[@]}"; do
  case "$lane" in
    all|fast|bench|examples) ;;
    *) echo "ci.sh: unknown lane '$lane' (lanes: all fast bench examples)" >&2
       exit 2 ;;
  esac
done
want() {
  local lane
  for lane in "${LANES[@]}"; do
    [[ "$lane" == "all" || "$lane" == "$1" ]] && return 0
  done
  return 1
}

if want fast; then
  echo "== pytest -m fast =="
  python -m pytest -q -m fast
fi

if want bench; then
  echo "== benchmarks/run.py --smoke =="
  python -m benchmarks.run --smoke
fi

if want examples; then
  echo "== examples smoke (reduced config) =="
  python examples/quickstart.py
  python examples/federated_training.py --smoke
fi

echo "ci.sh: all green (${LANES[*]})"
